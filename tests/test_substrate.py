"""Substrate tests: checkpointing, fault-tolerant supervisor, gradient
compression, data pipeline, SCAN<->data bridge."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint
from repro.data.pipeline import SyntheticLM, doc_similarity_graph
from repro.dist.fault_tolerance import Supervisor, SupervisorConfig
from repro.optim import adamw, grad_compress


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------
def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (16, 8)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": jax.random.normal(k, (4,)).astype(jnp.bfloat16)},
            "count": jnp.int32(7)}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    checkpoint.save(str(tmp_path), 3, t)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), t)
    out = checkpoint.restore(str(tmp_path), 3, like)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_latest_and_gc(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        checkpoint.save(str(tmp_path), s, t, keep=2)
    assert checkpoint.latest_step(str(tmp_path)) == 5
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_00000004", "step_00000005"]


def test_checkpoint_no_partial_commit(tmp_path):
    """A stale .tmp directory is never treated as a checkpoint."""
    t = _tree()
    checkpoint.save(str(tmp_path), 1, t)
    os.makedirs(tmp_path / "step_00000002.tmp")
    assert checkpoint.latest_step(str(tmp_path)) == 1


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    checkpoint.save(str(tmp_path), 1, {"a": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        checkpoint.restore(str(tmp_path), 1, {"a": jnp.zeros((5,))})


# --------------------------------------------------------------------------
# fault-tolerant supervisor (injected failures)
# --------------------------------------------------------------------------
def _toy_setup():
    params = {"w": jnp.ones((4,))}
    opt = adamw.init(params)
    hp = adamw.AdamWConfig(lr=0.1, warmup_steps=1, total_steps=100)

    def train_step(params, opt_state, batch):
        def loss(p):
            return jnp.sum((p["w"] - batch) ** 2)
        g = jax.grad(loss)(params)
        return adamw.update(g, opt_state, hp)

    def get_batch(step):
        return jnp.full((4,), float(step % 3))

    return params, opt, train_step, get_batch


def test_supervisor_restarts_after_failure(tmp_path):
    params, opt, train_step, get_batch = _toy_setup()
    fail_at = {7: 2}   # step 7 fails twice, then succeeds

    def flaky(params, opt_state, batch):
        step_val = int(opt_state["count"])
        if fail_at.get(step_val, 0) > 0:
            fail_at[step_val] -= 1
            raise RuntimeError("injected node failure")
        return train_step(params, opt_state, batch)

    sup = Supervisor(SupervisorConfig(ckpt_dir=str(tmp_path), ckpt_every=5,
                                      max_retries_per_step=5))
    state = sup.run({"params": params, "opt_state": opt, "step": 0},
                    flaky, get_batch, total_steps=12)
    kinds = [e[1] for e in sup.events]
    assert "failure" in kinds and "restart" in kinds
    assert int(state["step"]) == 12
    assert checkpoint.latest_step(str(tmp_path)) == 12


def test_supervisor_resume_from_checkpoint(tmp_path):
    params, opt, train_step, get_batch = _toy_setup()
    sup1 = Supervisor(SupervisorConfig(ckpt_dir=str(tmp_path), ckpt_every=4))
    s1 = sup1.run({"params": params, "opt_state": opt, "step": 0},
                  train_step, get_batch, total_steps=8)
    # a fresh supervisor resumes from the committed step
    sup2 = Supervisor(SupervisorConfig(ckpt_dir=str(tmp_path), ckpt_every=4))
    s2 = sup2.run({"params": params, "opt_state": opt, "step": 0},
                  train_step, get_batch, total_steps=12)
    assert int(s2["step"]) == 12
    assert ("resume" in [e[1] for e in sup2.events])


def test_straggler_detection():
    sup = Supervisor(SupervisorConfig(straggler_factor=2.0,
                                      straggler_patience=2))
    assert not sup.observe_step_time(0, 1.0)
    assert not sup.observe_step_time(1, 1.1)
    assert sup.observe_step_time(2, 5.0)
    assert sup.observe_step_time(3, 5.0)
    assert sup.straggler_persistent()


# --------------------------------------------------------------------------
# gradient compression
# --------------------------------------------------------------------------
def test_int8_roundtrip_error_bound():
    g = jnp.asarray(np.random.default_rng(0).standard_normal((777,)) * 3)
    q, s = grad_compress.quantize_int8(g)
    deq = grad_compress.dequantize_int8(q, s, g.shape)
    # absmax block quantization: error ≤ scale/2 per element
    per_block_scale = np.repeat(np.asarray(s)[: 1], 777)
    assert float(jnp.max(jnp.abs(deq - g))) <= float(np.max(np.asarray(s)))


def test_error_feedback_unbiased_over_time():
    """With error feedback the *accumulated* compressed signal tracks the
    accumulated true signal (bounded residual)."""
    rng = np.random.default_rng(1)
    residual = jnp.zeros((512,))
    total_true = np.zeros((512,))
    total_sent = np.zeros((512,))
    for _ in range(50):
        g = jnp.asarray(rng.standard_normal((512,)))
        q, s, residual = grad_compress.compress_int8_ef(g, residual)
        sent = grad_compress.dequantize_int8(q, s, g.shape)
        total_true += np.asarray(g)
        total_sent += np.asarray(sent)
    # residual is the exact gap
    np.testing.assert_allclose(total_true - total_sent, np.asarray(residual),
                               atol=1e-3)
    assert float(jnp.max(jnp.abs(residual))) < 0.1


def test_topk_ef():
    g = jnp.asarray(np.random.default_rng(2).standard_normal((256,)))
    (vals, idx), resid = grad_compress.compress_topk_ef(
        g, jnp.zeros((256,)), k=64)
    dense = grad_compress.topk_densify(vals, idx, (256,))
    np.testing.assert_allclose(np.asarray(dense + resid), np.asarray(g),
                               atol=1e-6)


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------
def test_pipeline_deterministic_and_stateless():
    d = SyntheticLM(vocab=100, seq_len=16, global_batch=8, seed=3)
    a = d.batch(5)
    b = d.batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = d.batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_pipeline_shard_slices_partition_global_batch():
    d = SyntheticLM(vocab=100, seq_len=16, global_batch=8, seed=4)
    full = d.batch(2)
    parts = [d.shard_slice(2, i, 4)["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts, axis=0),
                                  full["tokens"])


def test_labels_are_shifted_tokens():
    d = SyntheticLM(vocab=100, seq_len=16, global_batch=4, seed=5)
    b = d.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_doc_similarity_graph_scan_dedup():
    """Near-duplicate docs end up in the same SCAN cluster."""
    from repro.core import build_index, query
    rng = np.random.default_rng(6)
    base = rng.integers(0, 50, size=(1, 30))
    dups = np.repeat(base, 4, axis=0)          # 4 near-identical docs
    others = rng.integers(0, 50, size=(6, 30))
    docs = np.concatenate([dups, others])
    g = doc_similarity_graph(docs, shingle=3, min_shared=2)
    idx = build_index(g, "jaccard")
    res = query(idx, g, 2, 0.5)
    lab = np.asarray(res.labels)
    assert len({lab[0], lab[1], lab[2], lab[3]}) == 1 and lab[0] >= 0
