"""Training-path extras: chunked cross-entropy, remat policies, SP no-op,
supervisor-driven elastic restore shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import model as mdl
from repro.models import layers as L
from repro.train.train_step import loss_fn


def _cfg(**kw):
    base = dict(arch_id="t", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab=251, head_dim=16,
                dtype="float32", q_chunk=16)
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = mdl.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    return cfg, params, {"tokens": tokens, "labels": tokens}


def test_chunked_ce_matches_full(setup):
    cfg, params, batch = setup
    l1, _ = loss_fn(cfg, params, batch)
    l2, _ = loss_fn(cfg.scaled(ce_chunk=8), params, batch)
    assert abs(float(l1) - float(l2)) < 1e-5


def test_chunked_ce_grads_match(setup):
    cfg, params, batch = setup
    g1 = jax.grad(lambda p: loss_fn(cfg, p, batch)[0])(params)
    g2 = jax.grad(lambda p: loss_fn(cfg.scaled(ce_chunk=8), p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@pytest.mark.parametrize("policy", ["full", "dots"])
def test_remat_policies_same_loss_and_grads(setup, policy):
    cfg, params, batch = setup
    l0, _ = loss_fn(cfg.scaled(remat=False), params, batch)
    l1, _ = loss_fn(cfg.scaled(remat_policy=policy), params, batch)
    assert abs(float(l0) - float(l1)) < 1e-5
    g0 = jax.grad(lambda p: loss_fn(cfg.scaled(remat=False), p, batch)[0])(params)
    g1 = jax.grad(
        lambda p: loss_fn(cfg.scaled(remat_policy=policy), p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_sp_noop_on_single_device(setup):
    cfg, params, batch = setup
    L.set_sp_spec(None)
    l0, _ = loss_fn(cfg, params, batch)
    assert np.isfinite(float(l0))


def test_chunked_ce_all_families():
    """ce_chunk agrees with full CE for every model family."""
    fams = {
        "moe": _cfg(family="moe", n_kv_heads=4, n_experts=8, top_k=2,
                    d_ff=48, d_ff_dense=96, first_dense_layers=1,
                    capacity_factor=4.0),
        "ssm": _cfg(family="ssm", n_heads=0, n_kv_heads=0, d_ff=0,
                    ssm_state=8, ssm_head_dim=16, ssm_chunk=8),
        "hybrid": _cfg(family="hybrid", ssm_state=8, ssm_head_dim=16,
                       ssm_chunk=8, global_layers=(0,), window=16,
                       meta_tokens=8),
    }
    for name, cfg in fams.items():
        params = mdl.init_params(cfg, jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                    cfg.vocab)
        batch = {"tokens": tokens, "labels": tokens}
        l1, _ = loss_fn(cfg, params, batch)
        l2, _ = loss_fn(cfg.scaled(ce_chunk=8), params, batch)
        assert abs(float(l1) - float(l2)) < 2e-5, name
